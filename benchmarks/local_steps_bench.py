"""§3.2 local-update schedule ablation: H local steps between syncs.

The paper claims local updates "effectively reduce the number of cross-cloud
communications and improve overall efficiency" but gives no schedule. This
sweep quantifies the tradeoff the claim hides: sync traffic falls 1/H while
the per-cloud replicas drift between syncs, costing convergence on non-IID
data. Reported per H: total sync bytes per cloud, modeled wall-clock
(compute + QUIC cross-cloud transfer), and final loss at a fixed step
budget."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_results
from repro.configs import get_smoke_config
from repro.configs.base import FederatedConfig, TrainConfig
from repro.core.federated import FederatedTrainer
from repro.core.protocols import QUIC, Link, sync_wall_time
from repro.data import SyntheticCorpus, dirichlet_mixtures, federated_batch
from repro.models import build_model

STEPS = 96
SEQ = 48
PCB = 8
BETA = 0.05
N_CLOUDS = 3
H_SWEEP = (1, 2, 4, 8, 16)


def run():
    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, n_domains=4, noise=0.1)
    mix = dirichlet_mixtures(jax.random.PRNGKey(0), N_CLOUDS, 4, beta=BETA)
    link = Link()

    rows = {}
    for h in H_SWEEP:
        fed = FederatedConfig(n_clouds=N_CLOUDS, local_steps=h, aggregation="fedavg")
        tcfg = TrainConfig(steps=STEPS, lr=3e-3, warmup_steps=6)
        trainer = FederatedTrainer(model, fed, tcfg)
        state = trainer.init_state(jax.random.PRNGKey(1))
        step = jax.jit(trainer.train_step)
        losses = []
        t0 = time.time()
        for i in range(STEPS):
            key = jax.random.fold_in(jax.random.PRNGKey(7), i)
            batch = federated_batch(corpus, key, mix, PCB, SEQ)
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        wall = time.time() - t0
        sync_bytes = trainer.sync_bytes_per_cloud(state["global"]["params"])
        n_syncs = STEPS // h
        comm_s = n_syncs * sync_wall_time(sync_bytes, N_CLOUDS, QUIC, link)
        final = float(np.mean(losses[-8:]))
        rows[f"H={h}"] = {
            "final_loss": final,
            "syncs": n_syncs,
            "sync_bytes_per_cloud": int(sync_bytes),
            "total_comm_gb": sync_bytes * n_syncs / 1e9,
            "modeled_comm_seconds": comm_s,
            "wall_seconds": wall,
        }
        emit(
            f"local_steps/H={h}", wall / STEPS * 1e6,
            f"loss={final:.3f};comm={sync_bytes*n_syncs/1e9:.1f}GB;"
            f"quic_s={comm_s:.1f}",
        )
    save_results("local_steps", rows)
    return rows


if __name__ == "__main__":
    run()
