"""Render the ``BENCH_serve.json`` perf trajectory as a markdown table.

The serve-bench smoke run APPENDS one schema-2 entry per CI run to
``BENCH_serve.json`` at the repo root; this tool turns that trajectory
into a markdown table so the perf history is readable at a glance —
tokens/sec, TTFT p95, pool occupancy, preemptions, the prefix-cache
columns (hit rate, prefilled-token savings, CoW splits, suffix-dispatch
count, steady warm-round seconds) added with prefix sharing, the
tensor-parallel columns (shard count, sharded tokens/sec) added with
mesh-sharded serving, the fault-tolerance columns (migrations,
migrated requests, sheds, per-replica occupancy, routed tokens/sec) added
with the multi-replica router, the tiered/quantized-KV columns (int8
residency ratio and token agreement at an equal pool byte budget,
host-tier swap-ins, swap-vs-recompute resume walls) added with the
host↔device KV tier, and the speculative-decoding columns (same-params
draft acceptance, spec tokens/sec, target dispatches per emitted token,
dispatch-count reduction) added with draft-model lookahead.
Entries predating a column render as "—".
In CI it lands on the job's step summary page.

Output goes to ``$GITHUB_STEP_SUMMARY`` when set (the GitHub Actions
step-summary file), else stdout — so the same invocation works locally:

    PYTHONPATH=src:. python -m benchmarks.bench_report
    PYTHONPATH=src:. python -m benchmarks.bench_report --last 5
"""
from __future__ import annotations

import argparse
import json
import os

BENCH_SEED_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serve.json",
)

# (column header, entry key, format) — missing keys render as "—" so old
# entries (pre-paged, pre-prefix schema additions) still tabulate
COLUMNS = (
    ("when (UTC)", "timestamp", "{}"),
    ("tok/s", "tokens_per_second", "{:.1f}"),
    ("tok/s paged", "tokens_per_second_paged", "{:.1f}"),
    ("shards", "sharded_shards", "{}"),
    ("tok/s sharded", "tokens_per_second_sharded", "{:.1f}"),
    ("ttft p95 (s)", "ttft_p95", "{:.3f}"),
    ("lat p95 (s)", "latency_p95", "{:.3f}"),
    ("occ mean", "pool_occupancy_mean", "{:.0%}"),
    ("occ max", "pool_occupancy_max", "{:.0%}"),
    ("preempt", "pool_preemptions", "{}"),
    ("tight preempt", "pool_tight_preemptions", "{}"),
    ("prefill compiles", "prefill_compiles", "{}"),
    ("prefix hit", "prefix_hit_rate", "{:.0%}"),
    ("prefill saved", "prefix_prefill_saved_frac", "{:.0%}"),
    ("CoW", "prefix_cow_copies", "{}"),
    ("suffix", "prefix_suffix_dispatches", "{}"),
    ("suffix round (s)", "suffix_round_s", "{:.2f}"),
    ("int8 resident ×", "kv_int8_residency_ratio", "{:.1f}"),
    ("int8 agree", "kv_int8_token_agreement", "{:.0%}"),
    ("swap in", "tiered_swapped_in_pages", "{}"),
    ("swap wall (s)", "tiered_wall_swap_s", "{:.2f}"),
    ("recompute wall (s)", "tiered_wall_recompute_s", "{:.2f}"),
    ("spec accept", "spec_accept_rate", "{:.0%}"),
    ("spec tok/s", "spec_tok_s", "{:.1f}"),
    ("spec disp/tok", "spec_dispatches_per_token", "{:.2f}"),
    ("spec disp ×", "spec_dispatch_reduction", "{:.1f}"),
    ("migrations", "router_migrations", "{}"),
    ("migrated", "router_migrated_requests", "{}"),
    ("shed", "router_shed_requests", "{}"),
    ("replica occ", "router_replica_occupancy", "{}"),
    ("tok/s routed", "router_tokens_per_second", "{:.1f}"),
)


def _cell(entry: dict, key: str, fmt: str) -> str:
    val = entry.get(key)
    if val is None:
        return "—"
    if key == "timestamp":
        return str(val).replace("+00:00", "Z")
    if key == "router_replica_occupancy" and isinstance(val, list):
        return "/".join(f"{v:.0%}" for v in val)
    try:
        return fmt.format(val)
    except (ValueError, TypeError):
        return str(val)


def render(path: str = BENCH_SEED_PATH, last: int = 10) -> str:
    """Markdown for the newest ``last`` trajectory entries (oldest first,
    matching the file order, so the bottom row is the current run)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return f"### Serve bench trajectory\n\n_no readable {path}: {e}_\n"
    entries = data.get("entries") if isinstance(data, dict) else None
    if not isinstance(entries, list) or not entries:
        return "### Serve bench trajectory\n\n_trajectory is empty_\n"
    rows = entries[-last:]
    lines = [
        "### Serve bench trajectory "
        f"(last {len(rows)} of {len(entries)} entries)",
        "",
        "| " + " | ".join(h for h, _, _ in COLUMNS) + " |",
        "|" + "|".join("---" for _ in COLUMNS) + "|",
    ]
    for e in rows:
        lines.append(
            "| " + " | ".join(_cell(e, k, f) for _, k, f in COLUMNS) + " |"
        )
    lines.append("")
    return "\n".join(lines)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--path", default=BENCH_SEED_PATH,
                    help="trajectory file (default: repo-root BENCH_serve.json)")
    ap.add_argument("--last", type=int, default=10,
                    help="render at most this many newest entries")
    args = ap.parse_args(argv)
    md = render(args.path, last=args.last)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(md + "\n")
    print(md)


if __name__ == "__main__":
    main()
