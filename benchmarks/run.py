"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table2,table3,...]

Prints ``name,us_per_call,derived`` CSV rows; full payloads are saved to
benchmarks/results.json. Mapping to the paper:

    table2_comm         — Table 2 (communication overhead + training time)
    table3_convergence  — Table 3 (convergence accuracy + final loss)
    partitioning        — Table 1 row: fixed vs dynamic partitioning
    protocols_bench     — Table 1 row: gRPC vs QUIC (+ TCP, multiplexing)
    compression_bench   — §3.2 gradient compression ablation
    async_bench         — §3.3 async aggregation latency/accuracy claim
    local_steps_bench   — §3.2 local-update schedule (H) comm/convergence sweep
    kernels_bench       — Pallas kernel micro-benchmarks
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "table2_comm",
    "table3_convergence",
    "partitioning",
    "protocols_bench",
    "compression_bench",
    "async_bench",
    "local_steps_bench",
    "kernels_bench",
    "serve_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    selected = args.only.split(",") if args.only else MODULES

    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run()
        except Exception as e:  # keep the harness going; report at the end
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        for name, err in failures:
            print(f"# FAILED {name}: {err}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
