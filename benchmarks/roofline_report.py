"""Render §Dry-run / §Roofline markdown tables from experiments/dryrun.jsonl.

    PYTHONPATH=src python -m benchmarks.roofline_report [--jsonl PATH]

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI, 6.25 GB/s cross-pod DCN (50 Gbit/s cross-cloud).
"""
from __future__ import annotations

import argparse
import json


ADVICE = {
    # dominant-term → what would move it down (templated per kind below)
    ("memory", "decode"): "batch more sequences per step or quantize the KV cache "
                          "(int8 halves HBM traffic); decode is bandwidth-bound by nature",
    ("memory", "prefill"): "raise arithmetic intensity: larger q_chunk tiles, fuse "
                           "attention epilogues, avoid fp32 round-trips",
    ("memory", "training"): "fewer remat round-trips / larger microbatch (fits more "
                            "of the live set), bf16 master-grad accumulation",
    ("compute", "training"): "already near the MXU roof — only algorithmic cuts "
                             "(fewer FLOPs) help",
    ("compute", "prefill"): "already near the MXU roof — only algorithmic cuts help",
    ("compute", "decode"): "compute-bound decode is unusual; check for redundant "
                           "recompute in the step",
    ("collective", "training"): "cut sync traffic: compression (top-k/int8), more "
                                "local steps per sync, or overlap DCN with compute",
    ("collective", "decode"): "KV-cache sharding forces cross-pod gathers; keep "
                              "decode replicas pod-local",
    ("collective", "prefill"): "reshard activations so TP collectives stay on ICI",
}


def load(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    # keep the LAST record per key (later rows supersede)
    dedup: dict[tuple, dict] = {}
    for r in rows:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def fmt_s(sec: float) -> str:
    if sec >= 1.0:
        return f"{sec:.2f}s"
    if sec >= 1e-3:
        return f"{sec*1e3:.2f}ms"
    return f"{sec*1e6:.0f}µs"


def fmt_b(b: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if b >= div:
            return f"{b/div:.2f}{unit}"
    return f"{b:.0f}B"


def dryrun_table(rows: list[dict]) -> str:
    out = [
        "| arch | shape | mesh | status | HLO FLOPs/dev | HBM bytes/dev | "
        "ICI bytes/dev | DCN bytes/dev | temp mem/dev | collectives |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if "error" in r:
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAIL** "
                f"| — | — | — | — | — | {r['error'][:60]} |"
            )
            continue
        rr = r["roofline"]
        kinds = ", ".join(
            f"{k}:{fmt_b(v)}" for k, v in sorted(rr["collectives_by_kind"].items())
        ) or "none"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r['hlo_flops_per_device']:.3g} | {fmt_b(r['hlo_bytes_per_device'])} "
            f"| {fmt_b(rr['ici_link_bytes'])} | {fmt_b(rr['dcn_link_bytes'])} "
            f"| {fmt_b(r['memory']['temp_bytes'])} | {kinds} |"
        )
    return "\n".join(out)


def roofline_table(rows: list[dict]) -> str:
    """Single-pod (16x16) roofline: three terms + dominant + usefulness."""
    out = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_FLOPS/dev | useful ratio | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "16x16" or "error" in r:
            continue
        rr = r["roofline"]
        advice = ADVICE.get((r["dominant"], r["kind"]), "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rr['compute_s'])} "
            f"| {fmt_s(rr['memory_s'])} | {fmt_s(rr['collective_s'])} "
            f"| **{r['dominant']}** | {r['model_flops_per_device']:.3g} "
            f"| {r['useful_flops_ratio']:.2f} | {advice} |"
        )
    return "\n".join(out)


def interesting_pairs(rows: list[dict]) -> str:
    """Candidates for the three hillclimbs."""
    ok = [r for r in rows if "error" not in r and r["mesh"] == "16x16"]
    mp = [r for r in rows if "error" not in r and r["mesh"] == "2x16x16"]

    def frac(r):  # roofline fraction = useful compute / bound
        rr = r["roofline"]
        bound = max(rr["compute_s"], rr["memory_s"], rr["collective_s"])
        ideal = r["model_flops_per_device"] / 197e12
        return ideal / bound if bound else 0.0

    worst = min(ok, key=frac)
    coll = max(mp, key=lambda r: r["roofline"]["collective_s"])
    lines = [
        f"- worst roofline fraction (16x16): {worst['arch']} × {worst['shape']} "
        f"(fraction {frac(worst):.4f}, dominant {worst['dominant']})",
        f"- most collective-bound (2x16x16): {coll['arch']} × {coll['shape']} "
        f"(collective {fmt_s(coll['roofline']['collective_s'])}, "
        f"DCN {fmt_b(coll['roofline']['dcn_link_bytes'])}/dev)",
    ]
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="experiments/dryrun.jsonl")
    ap.add_argument("--section", default="all", choices=["all", "dryrun", "roofline"])
    args = ap.parse_args()
    rows = load(args.jsonl)
    n_ok = sum("error" not in r for r in rows)
    if args.section in ("all", "dryrun"):
        print(f"### Dry-run records ({n_ok}/{len(rows)} combinations compile)\n")
        print(dryrun_table(rows))
        print()
    if args.section in ("all", "roofline"):
        print("### Roofline (single-pod 16×16, per device)\n")
        print(roofline_table(rows))
        print()
        print("### Hillclimb candidates\n")
        print(interesting_pairs(rows))


if __name__ == "__main__":
    main()
