"""Kernel micro-benchmarks: wall time of the pure-jnp reference path (the
CPU production path) and derived TPU-side arithmetic-intensity estimates for
each Pallas kernel. Interpret-mode timings are not meaningful hardware
numbers, so the derived column reports the kernel's bytes/elem roofline
character instead — EXCEPT the paged-decode occupancy sweep, where the
paged/unpaged ratio at fixed occupancy is the point: page skipping removes
whole grid steps, which interpret mode reproduces faithfully.

``--smoke`` shrinks sizes/iters to the CI budget (runs in CI next to
``serve_bench --smoke``).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_results
from repro.kernels import ops


def bench(fn, *args, iters=20):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) / iters * 1e6


def bench_min(fn, *args, iters=5):
    """Min-of-N wall time (µs): the robust estimator for the noisy
    interpret-mode kernel timings the occupancy sweep compares."""
    jax.block_until_ready(fn(*args))  # compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        best = min(best, time.time() - t0)
    return best * 1e6


def decode_occupancy_sweep(
    occupancies: dict, *, slots: int = 4, cap: int = 4096, hkv: int = 2,
    g: int = 2, hd: int = 64, iters: int = 5,
) -> dict:
    """SHARED probe (also driven by serve_bench): time the paged and the
    unpaged decode kernel, plus the PAGE-TABLE kernel over an equivalent
    shared pool, for each ``occupancies[label]`` position vector, returning
    ``{f"{paged|unpaged|table}_{label}_us": µs}``.

    The paged kernel's win scales with how much of the ring the live spans
    leave dead; the unpaged kernel streams cap slots per row regardless,
    so the low-occupancy rows are the load-bearing comparison. At full
    occupancy both kernels visit every page — any residual gap there is
    interpret-mode dispatch overhead, not page skipping, and should be
    read as noise. The cap must split into several auto-sized (512-slot)
    pages for skipping to exist at all.

    The ``table_*`` rows run the page-table mode (kernels/paged_decode.py
    pool layout) with each slot's pages deliberately SCATTERED across the
    pool — the indirection cost on top of ring-paged skipping is exactly
    the table_* − paged_* gap."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (slots, hkv, g, hd), jnp.bfloat16)
    kc = jax.random.normal(ks[1], (slots, cap, hkv, hd), jnp.bfloat16)
    vc = jax.random.normal(ks[2], (slots, cap, hkv, hd), jnp.bfloat16)
    # page-table layout of the SAME values: slot s's logical page j lands
    # at pool page 1 + j·slots + s (strided interleave — every logical
    # step jumps ``slots`` pages, the worst case for a contiguous reader;
    # pool page 0 is the reserved scratch page)
    # same page size the contiguous paged kernel auto-picks (_chunk), so
    # the table_* − paged_* gap isolates indirection, not partitioning
    from repro.kernels.swa_decode import _chunk

    page = _chunk(cap)
    t_w = cap // page
    flat_k = kc.reshape(slots * t_w, page, hkv, hd)   # row s·t_w + j
    flat_v = vc.reshape(slots * t_w, page, hkv, hd)
    idx = jnp.arange(slots * t_w)
    dest = 1 + (idx % t_w) * slots + idx // t_w       # (s, j) → 1 + j·slots + s
    pool_shape = (1 + slots * t_w, page, hkv, hd)
    pool_k = jnp.zeros(pool_shape, jnp.bfloat16).at[dest].set(flat_k)
    pool_v = jnp.zeros(pool_shape, jnp.bfloat16).at[dest].set(flat_v)
    table = dest.reshape(slots, t_w).astype(jnp.int32)
    # one jitted fn per variant, shared across labels — pos shape/dtype is
    # identical for every label, so each compiles exactly once
    fns = {
        "paged": jax.jit(
            lambda p: ops.swa_decode_attention(
                q, kc, vc, p, 0, use_kernel=True, paged=True, interpret=True
            )
        ),
        "unpaged": jax.jit(
            lambda p: ops.swa_decode_attention(
                q, kc, vc, p, 0, use_kernel=True, paged=False, interpret=True
            )
        ),
        "table": jax.jit(
            lambda p: ops.swa_decode_attention(
                q, pool_k, pool_v, p, 0, use_kernel=True, table=table,
                interpret=True,
            )
        ),
    }
    out = {}
    pos_arrs = {
        label: jnp.asarray(pos, jnp.int32)
        for label, pos in occupancies.items()
    }
    # warm EVERY (variant, label) dispatch before any timing. bench_min
    # already excludes each call's own compile, but the first variant timed
    # would still absorb one-time process costs (allocator growth, dispatch
    # caches) that later variants inherit for free — min-of-N cannot remove
    # a bias that never recurs, so pay all of it up front.
    for fn in fns.values():
        for pos in pos_arrs.values():
            jax.block_until_ready(fn(pos))
    for label, pos in pos_arrs.items():
        for variant, fn in fns.items():
            out[f"{variant}_{label}_us"] = bench_min(fn, pos, iters=iters)
    return out


def suffix_occupancy_sweep(
    start_levels: dict, *, rows: int = 2, suffix: int = 16, page: int = 16,
    t_w: int = 8, hkv: int = 2, g: int = 2, hd: int = 64, iters: int = 5,
) -> dict:
    """Suffix-prefill kernel time vs. cached-prefix depth over a SCATTERED
    paged pool (same strided layout as ``decode_occupancy_sweep``: row r's
    logical page j sits at pool page 1 + j·rows + r, so every logical step
    jumps ``rows`` pool pages).

    Two effects, both reproduced faithfully by interpret mode because each
    removes whole grid steps:

    * ``full_*`` rows fix the static prefix width at the table width — the
      ``pl.when`` dead-page skip is the only lever, so the shallow-vs-deep
      gap is pure page skipping;
    * ``bucket_*`` rows ALSO shrink the static width to the pow2 bucket
      covering ``max(starts)`` (``launch/engine.py::bucket_pages`` — the
      engine's start-bucket ladder); the saving over ``full_*`` at the
      same depth is the grid truncation the ladder buys on top.

    ``ref_us`` times the displaced jnp gather-concat path once — its cost
    is depth-independent (it always gathers the full table width), which
    is exactly why the kernel exists."""
    from repro.kernels.flash_suffix_prefill import suffix_prefill
    from repro.launch.engine import bucket_pages

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (rows, suffix, hkv, g, hd), jnp.bfloat16)
    k_suf = jax.random.normal(ks[1], (rows, suffix, hkv, hd), jnp.bfloat16)
    v_suf = jax.random.normal(ks[2], (rows, suffix, hkv, hd), jnp.bfloat16)
    pool_shape = (1 + rows * t_w, page, hkv, hd)
    pool_k = jax.random.normal(ks[3], pool_shape, jnp.bfloat16)
    pool_v = jax.random.normal(ks[4], pool_shape, jnp.bfloat16)
    idx = jnp.arange(rows * t_w)
    dest = 1 + (idx % t_w) * rows + idx // t_w    # (r, j) → 1 + j·rows + r
    table = dest.reshape(rows, t_w).astype(jnp.int32)

    def kernel_fn(width):
        return lambda s: suffix_prefill(
            q, k_suf, v_suf, pool_k, pool_v, table, s,
            prefix_width=width, interpret=True,
        )

    out = {}
    for label, start_tokens in start_levels.items():
        starts = jnp.full((rows,), int(start_tokens), jnp.int32)
        wb = bucket_pages(-(-int(start_tokens) // page), t_w)
        for variant, width in (("full", t_w), ("bucket", wb)):
            if variant == "bucket" and width == t_w:
                continue  # same trace as full_* — nothing new to time
            us = bench_min(kernel_fn(width), starts, iters=iters)
            out[f"{variant}_{label}_us"] = us
    ref_fn = jax.jit(
        lambda s: ops.suffix_prefill_attention(
            q, k_suf, v_suf, pool_k, pool_v, table, s, prefix_width=t_w
        )
    )
    out["ref_us"] = bench_min(
        ref_fn, jnp.full((rows,), t_w * page, jnp.int32), iters=iters
    )
    return out


def bench_suffix_occupancy(rows: dict, *, smoke: bool) -> None:
    """Suffix-prefill kernel across cached-prefix depths: shallow (one live
    page of the table) vs. deep (every page live), full static width vs.
    the engine's start bucket."""
    page, t_w = 16, 8
    iters = 3 if smoke else 6
    start_levels = {
        "shallow": page,          # 1 of t_w pages live → 7 skipped
        "deep": t_w * page,       # every page live → nothing to skip
    }
    sweep = suffix_occupancy_sweep(
        start_levels, page=page, t_w=t_w, iters=iters
    )
    for key, us in sweep.items():
        name = f"suffix_{key[: -len('_us')]}"
        rows[name] = us
        detail = (
            "jnp gather-concat path (depth-independent)" if key == "ref_us"
            else f"table_width={t_w};page={page}"
        )
        emit(f"kernels/{name}", us, detail)


def bench_decode_occupancy(rows: dict, *, smoke: bool) -> None:
    """Paged vs. unpaged decode kernel across ring occupancy levels.

    Two axes: every-slot depth (all shallow vs. all past wrap) and MIXED
    occupancy (one deep slot among freshly reset ones — the continuous-
    batching engine's steady state right after a backfill)."""
    slots, cap = 4, (2048 if smoke else 4096)
    iters = 3 if smoke else 8
    shallow = 16
    occupancies = {
        "1live": [cap + 5] + [shallow] * (slots - 1),
        "alllive": [cap + 5] * slots,
        "allshallow": [shallow] * slots,
    }
    sweep = decode_occupancy_sweep(
        occupancies, slots=slots, cap=cap, iters=iters
    )
    if smoke:
        # CI guard: page skipping must make an all-shallow paged decode
        # cheaper than a full-ring unpaged one — the probe's load-bearing
        # contrast. A silently broken skip path (kernel visiting dead
        # pages) would otherwise hide inside timing noise.
        assert sweep["paged_allshallow_us"] < sweep["unpaged_alllive_us"], (
            "occupancy probe inverted: shallow paged decode "
            f"({sweep['paged_allshallow_us']:.0f}us) should beat full "
            f"unpaged ({sweep['unpaged_alllive_us']:.0f}us) — page "
            "skipping is not skipping"
        )
    for key, us in sweep.items():
        variant, label, _ = key.split("_", 2)
        name = f"decode_{variant}_{label}"
        rows[name] = us
        emit(
            f"kernels/{name}", us,
            f"cap={cap};pages_live={'mixed' if label == '1live' else label}",
        )


def run(argv: list[str] | None = None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: ONLY the paged-decode occupancy "
                    "sweep, at smaller shapes/iters")
    args = ap.parse_args(argv if argv is not None else [])

    rows = {}
    if args.smoke:
        # CI cares about the paged/unpaged occupancy contrast; the legacy
        # full-size rows (1M-element refs, 8k-ring decode, flash prefill)
        # would dominate the step's wall time for no signal
        bench_decode_occupancy(rows, smoke=True)
        bench_suffix_occupancy(rows, smoke=True)
        save_results("kernels_smoke", rows)
        return rows

    key = jax.random.PRNGKey(0)

    x = jax.random.normal(key, (1 << 20,))  # 1M-element gradient leaf
    us = bench(jax.jit(lambda v: ops.topk_sparsify_leaf(v, 0.01)), x)
    rows["topk_ref_1M"] = us
    emit("kernels/topk_1M", us, "hbm=8B/elem;compute=k·max/256elem")

    us = bench(jax.jit(lambda v: ops.int8_roundtrip_leaf(v)), x)
    rows["int8_ref_1M"] = us
    emit("kernels/int8_1M", us, "hbm=8B/elem;compute=3flop/elem")

    tree = {"a": x, "b": jax.random.normal(key, (1 << 18,))}
    us = bench(
        jax.jit(lambda t: ops.dp_transmit(t, key, 1.0, 0.1)), tree
    )
    rows["dp_transmit_1.25M"] = us
    emit("kernels/dp_transmit", us, "two-pass;hbm=12B/elem")

    q = jax.random.normal(key, (4, 8, 4, 128), jnp.bfloat16)
    kc = jax.random.normal(key, (4, 8192, 8, 128), jnp.bfloat16)
    vc = jax.random.normal(key, (4, 8192, 8, 128), jnp.bfloat16)
    us = bench(
        jax.jit(lambda a, b, c: ops.swa_decode_attention(a, b, c, jnp.asarray(9000), 8192)),
        q, kc, vc,
    )
    rows["swa_decode_ref_8k_window"] = us
    emit("kernels/swa_decode_8k", us, "hbm-bound:2·C·Hkv·hd·2B/token")

    bench_decode_occupancy(rows, smoke=False)
    bench_suffix_occupancy(rows, smoke=False)

    # flash prefill attention (causal GQA): ref oracle at CPU-feasible size.
    # HBM model: flash = O(Q+K+V+O) vs naive = O(S²·H) probs materialized.
    qf = jax.random.normal(key, (2, 512, 4, 4, 64), jnp.bfloat16)
    kf = jax.random.normal(key, (2, 512, 4, 64), jnp.bfloat16)
    vf = jax.random.normal(key, (2, 512, 4, 64), jnp.bfloat16)
    us = bench(
        jax.jit(lambda a, b, c: ops.flash_prefill_attention(a, b, c, causal=True)),
        qf, kf, vf,
    )
    rows["flash_prefill_ref_512"] = us
    emit("kernels/flash_prefill_512", us, "vmem-resident softmax;hbm=Q+K+V+O")

    save_results("kernels", rows)
    return rows


if __name__ == "__main__":
    import sys

    run(sys.argv[1:])
