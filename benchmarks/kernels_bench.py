"""Kernel micro-benchmarks: wall time of the pure-jnp reference path (the
CPU production path) and derived TPU-side arithmetic-intensity estimates for
each Pallas kernel. Interpret-mode timings are not meaningful hardware
numbers, so the derived column reports the kernel's bytes/elem roofline
character instead."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_results
from repro.kernels import ops


def bench(fn, *args, iters=20):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) / iters * 1e6


def run() -> dict:
    key = jax.random.PRNGKey(0)
    rows = {}

    x = jax.random.normal(key, (1 << 20,))  # 1M-element gradient leaf
    us = bench(jax.jit(lambda v: ops.topk_sparsify_leaf(v, 0.01)), x)
    rows["topk_ref_1M"] = us
    emit("kernels/topk_1M", us, "hbm=8B/elem;compute=k·max/256elem")

    us = bench(jax.jit(lambda v: ops.int8_roundtrip_leaf(v)), x)
    rows["int8_ref_1M"] = us
    emit("kernels/int8_1M", us, "hbm=8B/elem;compute=3flop/elem")

    tree = {"a": x, "b": jax.random.normal(key, (1 << 18,))}
    us = bench(
        jax.jit(lambda t: ops.dp_transmit(t, key, 1.0, 0.1)), tree
    )
    rows["dp_transmit_1.25M"] = us
    emit("kernels/dp_transmit", us, "two-pass;hbm=12B/elem")

    q = jax.random.normal(key, (4, 8, 4, 128), jnp.bfloat16)
    kc = jax.random.normal(key, (4, 8192, 8, 128), jnp.bfloat16)
    vc = jax.random.normal(key, (4, 8192, 8, 128), jnp.bfloat16)
    us = bench(
        jax.jit(lambda a, b, c: ops.swa_decode_attention(a, b, c, jnp.asarray(9000), 8192)),
        q, kc, vc,
    )
    rows["swa_decode_ref_8k_window"] = us
    emit("kernels/swa_decode_8k", us, "hbm-bound:2·C·Hkv·hd·2B/token")

    # flash prefill attention (causal GQA): ref oracle at CPU-feasible size.
    # HBM model: flash = O(Q+K+V+O) vs naive = O(S²·H) probs materialized.
    qf = jax.random.normal(key, (2, 512, 4, 4, 64), jnp.bfloat16)
    kf = jax.random.normal(key, (2, 512, 4, 64), jnp.bfloat16)
    vf = jax.random.normal(key, (2, 512, 4, 64), jnp.bfloat16)
    us = bench(
        jax.jit(lambda a, b, c: ops.flash_prefill_attention(a, b, c, causal=True)),
        qf, kf, vf,
    )
    rows["flash_prefill_ref_512"] = us
    emit("kernels/flash_prefill_512", us, "vmem-resident softmax;hbm=Q+K+V+O")

    save_results("kernels", rows)
    return rows


if __name__ == "__main__":
    run()
