"""Paper Table 2: communication overhead (GB) and training time (hours) for
FedAvg / Dynamic Weighted / Gradient Aggregation.

Reproduction protocol (DESIGN.md §8): the paper gives absolute GB/hours on an
unspecified "pre-trained language model" over 100 rounds on 3 clouds. We
reproduce the *experiment design*: same three aggregators, 100 rounds,
3 clouds, and report (a) measured wire bytes from the framework's own sync
accounting on the full-size stablelm-1.6b parameter set, (b) wall-clock
modeled with the scheduler + QUIC link model. The paper's qualitative
orderings (gradient < dynamic < fedavg on both columns) are asserted in
EXPERIMENTS.md §Claims.

Why the orderings come out this way here:
* fedavg/dynamic sync parameter DELTAS every H=4 local steps — dynamic adds
  a scalar loss exchange (negligible) but its faster convergence means fewer
  rounds-to-target (time column).
* gradient aggregation syncs EVERY step, but int8-compressed gradients
  (the paper notes "smaller data volume during aggregation"); per-round
  bytes are 4× smaller, and convergence-per-step is higher.
"""
from __future__ import annotations

import jax

from benchmarks.common import Timer, emit, save_results
from repro.configs import get_config, get_smoke_config
from repro.configs.base import FederatedConfig, TrainConfig
from repro.core.compression import Compressor
from repro.core.federated import FederatedTrainer
from repro.core.protocols import QUIC, Link, sync_wall_time
from repro.core.scheduler import CloudSpec, sync_round_time
from repro.models import build_model

ROUNDS = 100
N_CLOUDS = 3
H = 4

# per-aggregator wire configuration (paper §3.2/§3.3 pairings)
CONFIGS = {
    "fedavg": dict(aggregation="fedavg", compression="none", syncs=ROUNDS, payload="delta"),
    "dynamic_weighted": dict(aggregation="dynamic", compression="none", syncs=ROUNDS, payload="delta"),
    "gradient_aggregation": dict(aggregation="gradient", compression="int8", syncs=ROUNDS * H, payload="grad"),
}


def reference_params():
    """Full-size stablelm-1.6b parameter pytree SHAPES (no allocation)."""
    cfg = get_config("stablelm-1.6b")
    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0)), cfg


def run() -> dict:
    params_shapes, cfg = reference_params()
    link = Link(latency_s=0.03, bandwidth=1.25e9, loss_rate=1e-4)
    clouds = [CloudSpec(f"c{i}", speed=1.0 + 0.3 * i) for i in range(N_CLOUDS)]
    # nominal per-local-step compute time for a 1.6B model on one cloud's
    # accelerator slice (256 v5e chips, ~40% MFU): 6·N·B·S / (chips·peak·MFU)
    step_flops = 6 * cfg.param_count() * 256 * 4096
    step_time = step_flops / (256 * 197e12 * 0.4)

    rows = {}
    for name, c in CONFIGS.items():
        comp = Compressor(c["compression"], topk_ratio=0.01)
        per_sync = comp.bytes_per_sync(params_shapes)
        total_gb = per_sync * c["syncs"] * N_CLOUDS / 1e9
        comm_time = c["syncs"] * sync_wall_time(per_sync, N_CLOUDS, QUIC, link)
        compute_time = (
            ROUNDS * H * max(step_time / s.speed for s in clouds)
        )
        hours = (comm_time + compute_time) / 3600
        rows[name] = {
            "bytes_per_cloud_per_sync": per_sync,
            "syncs": c["syncs"],
            "comm_overhead_gb": total_gb,
            "comm_seconds": comm_time,
            "compute_seconds": compute_time,
            "training_time_hours": hours,
        }
        emit(
            f"table2/{name}",
            comm_time / c["syncs"] * 1e6,
            f"comm_gb={total_gb:.1f};hours={hours:.2f}",
        )
    save_results("table2_comm", rows)
    return rows


if __name__ == "__main__":
    run()
