"""Shared benchmark harness utilities."""
from __future__ import annotations

import json
import os
import time

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "results.json")


def emit(name: str, us_per_call: float, derived: str):
    """The harness's CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def save_results(key: str, payload):
    data = {}
    if os.path.exists(RESULTS_PATH):
        with open(RESULTS_PATH) as f:
            try:
                data = json.load(f)
            except Exception:
                data = {}
    data[key] = payload
    with open(RESULTS_PATH, "w") as f:
        json.dump(data, f, indent=1)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
